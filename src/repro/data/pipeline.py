"""Batching pipeline for the FL simulation: per-client epoch iterators with
deterministic shuffling, plus a balanced held-out eval set (the paper tests
the global model on a balanced set).

Two consumers share one batch-order contract:

* the sequential engine iterates ``ClientDataset.batches`` client by client;
* the vmap engine (``repro.fl.batched``) materialises the *same* order via
  ``batch_plan`` and stacks the selected clients along a leading client axis.

Ragged clients (different dataset sizes => different step counts) are handled
by **pad-and-mask**: every client in a bucket is padded to the bucket's max
step count with repeated batches whose ``step_valid`` entry is 0 — padded
steps are computed but discarded, so results match the sequential oracle.
Clients smaller than the batch size train with ``bs = len(client)`` (exactly
like the sequential path); since a compiled program needs one static batch
shape, such clients land in their own *bucket* keyed by ``bs``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


def batch_plan(n: int, batch_size: int, epochs: int, seed: int) -> np.ndarray:
    """Deterministic batch-index plan: ``(steps, bs)`` int array.

    ``epochs`` passes of shuffled, truncated-to-full batches (at least one
    batch per epoch even if the client has < batch_size samples).  This is
    THE batch-order contract: both engines derive their batches from it.
    """
    rng = np.random.default_rng(seed)
    bs = min(batch_size, n)
    rows = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for start in range(0, max(n - bs + 1, 1), bs):
            rows.append(order[start : start + bs])
    return np.stack(rows).astype(np.int64)


@dataclasses.dataclass
class ClientDataset:
    inputs: np.ndarray      # images (N,H,W,C) or tokens (N,S)
    labels: np.ndarray      # (N,)

    def __len__(self) -> int:
        return len(self.labels)

    def batches(
        self, batch_size: int, epochs: int, seed: int
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """``epochs`` passes of shuffled, truncated-to-full batches (at least
        one batch per epoch even if the client has < batch_size samples)."""
        for idx in batch_plan(len(self), batch_size, epochs, seed):
            yield self.inputs[idx], self.labels[idx]


@dataclasses.dataclass
class StackedClientBatches:
    """One bucket of same-batch-width clients, stacked along a client axis.

    ``inputs``/``labels`` carry a leading ``(clients, steps, bs, ...)`` shape;
    ``step_valid`` is ``(clients, steps)`` float32 — 0.0 marks padded steps
    whose results the batched engine discards (the pad-and-mask contract).
    ``members`` maps bucket rows back to positions in the round's picked-client
    order.  When the bucket was built with ``pad_clients_to > 1`` the client
    axis may carry trailing *padding clients* (rows ``>= len(members)``):
    copies of the first member with ``step_valid`` all zero, so they train
    nothing — the engine gives them zero aggregation weight and slices them
    off every per-client output.
    """

    inputs: np.ndarray
    labels: np.ndarray
    step_valid: np.ndarray
    members: tuple[int, ...]

    @property
    def num_clients(self) -> int:
        return self.inputs.shape[0]

    @property
    def num_real(self) -> int:
        """Clients that correspond to actual round participants."""
        return len(self.members)

    @property
    def num_steps(self) -> int:
        return self.inputs.shape[1]

    @property
    def batch_width(self) -> int:
        return self.inputs.shape[2]


def stack_client_batches(
    datasets: Sequence[ClientDataset],
    batch_size: int,
    epochs: int,
    seeds: Sequence[int],
    *,
    pad_clients_to: int = 1,
) -> list[StackedClientBatches]:
    """Stack the round's clients into vmap-ready buckets.

    Clients are bucketed by effective batch width ``min(batch_size, n)`` (one
    compiled program per width); within a bucket, ragged step counts are
    padded with the client's first batch and masked out via ``step_valid``.

    ``pad_clients_to`` rounds each bucket's *client axis* up to a multiple of
    the given value by appending padding clients (first member's data,
    ``step_valid`` all zero).  The shard_map engine uses this so every device
    in the mesh receives the same per-shard client count; padding clients get
    zero aggregation weight, so results are unchanged (see
    ``StackedClientBatches``).
    """
    if len(datasets) != len(seeds):
        raise ValueError("one seed per client dataset is required")
    if pad_clients_to < 1:
        raise ValueError(f"pad_clients_to must be >= 1, got {pad_clients_to}")
    buckets: dict[int, list[int]] = {}
    for pos, ds in enumerate(datasets):
        buckets.setdefault(min(batch_size, len(ds)), []).append(pos)

    out = []
    for bs in sorted(buckets):
        members = buckets[bs]
        plans = [batch_plan(len(datasets[p]), batch_size, epochs, seeds[p])
                 for p in members]
        max_steps = max(len(pl) for pl in plans)
        xs, ys, valid = [], [], []
        for p, plan in zip(members, plans):
            pad = max_steps - len(plan)
            if pad:
                plan = np.concatenate([plan, np.repeat(plan[:1], pad, axis=0)])
            xs.append(datasets[p].inputs[plan])
            ys.append(datasets[p].labels[plan])
            v = np.zeros(max_steps, dtype=np.float32)
            v[: max_steps - pad] = 1.0
            valid.append(v)
        n_pad = -len(members) % pad_clients_to
        for _ in range(n_pad):
            xs.append(xs[0])
            ys.append(ys[0])
            valid.append(np.zeros(max_steps, dtype=np.float32))
        out.append(StackedClientBatches(
            inputs=np.stack(xs), labels=np.stack(ys),
            step_valid=np.stack(valid), members=tuple(members),
        ))
    return out


def build_clients(
    inputs: np.ndarray, labels: np.ndarray, parts: list[np.ndarray]
) -> list[ClientDataset]:
    return [ClientDataset(inputs[p], labels[p]) for p in parts]


def balanced_eval_set(
    inputs: np.ndarray, labels: np.ndarray, per_class: int, seed: int = 1
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    picks = []
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        picks.append(rng.choice(idx, size=min(per_class, len(idx)), replace=False))
    sel = np.concatenate(picks)
    rng.shuffle(sel)
    return inputs[sel], labels[sel]
