from repro.data.synthetic import (  # noqa: F401
    TextDatasetSpec,
    VisionDatasetSpec,
    make_text_dataset,
    make_vision_dataset,
)
from repro.data.partitioner import (  # noqa: F401
    dirichlet_partition,
    iid_partition,
    partition_stats,
)
from repro.data.pipeline import ClientDataset, balanced_eval_set, build_clients  # noqa: F401
