from repro.data.synthetic import (  # noqa: F401
    TextDatasetSpec,
    VisionDatasetSpec,
    make_text_dataset,
    make_vision_dataset,
)
from repro.data.partitioner import (  # noqa: F401
    dirichlet_partition,
    iid_partition,
    partition_stats,
)
from repro.data.pipeline import (  # noqa: F401
    ClientDataset,
    StackedClientBatches,
    balanced_eval_set,
    batch_plan,
    build_clients,
    stack_client_batches,
)
