"""Synthetic datasets (the container is offline — no CIFAR/TinyImageNet).

Vision: class-conditional images built from per-class low-frequency pattern +
per-class color statistics + noise.  The task is learnable (a linear probe
fails, a small CNN succeeds) so convergence-speed comparisons between FNU
and FedPart are meaningful — the paper's *directional* claims are validated
on it (EXPERIMENTS.md records the caveat).

Text: token sequences from class-dependent Markov chains over a shared
vocabulary — a classification task matching the paper's AGNews/SogouNews
setup in spirit.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VisionDatasetSpec:
    num_classes: int = 20
    image_size: int = 32
    channels: int = 3
    noise: float = 0.35
    proto_seed: int = 1234      # class prototypes are a property of the TASK:
    name: str = "synth-cifar"   # train/eval splits share them (sample seed differs)


def _draw_labels(rng: np.random.Generator, num_classes: int, num_samples: int,
                 class_probs=None) -> np.ndarray:
    """Uniform labels (the default, bit-identical to the historical stream)
    or ``class_probs``-weighted ones — per-client label skew for populations
    whose shards are synthesized from (seed, client_id) rather than
    partitioned from one global array (``fl.population``)."""
    if class_probs is None:
        return rng.integers(0, num_classes, num_samples).astype(np.int32)
    p = np.asarray(class_probs, dtype=np.float64)
    if p.shape != (num_classes,):
        raise ValueError(f"class_probs shape {p.shape} != ({num_classes},)")
    return rng.choice(num_classes, size=num_samples, p=p / p.sum()).astype(np.int32)


def make_vision_dataset(
    spec: VisionDatasetSpec, num_samples: int, seed: int = 0,
    class_probs=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (N,H,W,C) float32 in [-1,1], labels (N,) int32)."""
    proto_rng = np.random.default_rng(spec.proto_seed)
    rng = np.random.default_rng(seed)
    h = w = spec.image_size
    # Per-class pattern: mixture of low-frequency sinusoids + color bias —
    # drawn from the spec's proto_seed so every split sees the same task.
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    protos = np.zeros((spec.num_classes, h, w, spec.channels), np.float32)
    for c in range(spec.num_classes):
        fx, fy = proto_rng.uniform(0.5, 3.0, 2)
        phase = proto_rng.uniform(0, 2 * np.pi, 2)
        base = np.sin(2 * np.pi * fx * xx / w + phase[0]) * np.cos(
            2 * np.pi * fy * yy / h + phase[1]
        )
        color = proto_rng.uniform(-0.8, 0.8, spec.channels)
        protos[c] = base[..., None] * 0.6 + color[None, None, :] * 0.4

    labels = _draw_labels(rng, spec.num_classes, num_samples, class_probs)
    images = protos[labels] + rng.normal(0, spec.noise, (num_samples, h, w, spec.channels))
    return images.astype(np.float32), labels


@dataclasses.dataclass(frozen=True)
class TextDatasetSpec:
    num_classes: int = 4
    vocab_size: int = 512
    seq_len: int = 64
    proto_seed: int = 1234
    name: str = "synth-agnews"


def make_text_dataset(
    spec: TextDatasetSpec, num_samples: int, seed: int = 0,
    class_probs=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Class-dependent Markov chains: (tokens (N,S) int32, labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    # Per-class transition structure (task-level: shared across splits).
    proto_rng = np.random.default_rng(spec.proto_seed)
    succ = proto_rng.integers(0, spec.vocab_size, (spec.num_classes, spec.vocab_size, 4))
    labels = _draw_labels(rng, spec.num_classes, num_samples, class_probs)
    tokens = np.zeros((num_samples, spec.seq_len), np.int32)
    tokens[:, 0] = rng.integers(0, spec.vocab_size, num_samples)
    follow = rng.random((num_samples, spec.seq_len)) < 0.8
    choice = rng.integers(0, 4, (num_samples, spec.seq_len))
    rand_tok = rng.integers(0, spec.vocab_size, (num_samples, spec.seq_len))
    for t in range(1, spec.seq_len):
        preferred = succ[labels, tokens[:, t - 1], choice[:, t]]
        tokens[:, t] = np.where(follow[:, t], preferred, rand_tok[:, t])
    return tokens, labels
