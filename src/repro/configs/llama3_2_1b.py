"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.  Tied embeddings,
RoPE theta 500k, SwiGLU, RMSNorm.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        kind="decoder",
        source="hf:meta-llama/Llama-3.2-1B",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta=500_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("llama3.2-1b", full, smoke)
