"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 256e top-8.
First 3 layers dense (d_ff=18432), remaining 58 MoE.  MLA: q_lora 1536,
kv_lora 512, qk nope/rope 128/64, v 128 — the compressed latent cache
(512+64 per token per layer) is what makes the 500k decode shape feasible.
Sigmoid router scores (deepseek-v3), one shared expert, MTP depth 1.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        kind="decoder",
        source="arXiv:2412.19437",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=18432,               # dense (first 3) layers
        vocab_size=129280,
        num_experts=256,
        num_experts_per_tok=8,
        num_shared_experts=1,
        moe_d_ff=2048,
        first_dense_layers=3,
        router_score="sigmoid",
        capacity_factor=1.25,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mtp_depth=1,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=2,
        moe_d_ff=64,
        first_dense_layers=1,
        q_lora_rank=32,
        kv_lora_rank=32,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        mtp_depth=0,
        capacity_factor=8.0,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("deepseek-v3-671b", full, smoke)
