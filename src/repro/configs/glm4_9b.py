"""glm4-9b [dense] — RoPE, GQA [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        kind="decoder",
        source="hf:THUDM/glm-4-9b",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("glm4-9b", full, smoke)
