"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        kind="decoder",
        source="arXiv:2401.02385",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=64,
        d_ff=5632,
        vocab_size=32000,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("tinyllama-1.1b", full, smoke)
