"""Architecture configs: 10 assigned archs + the paper's own models.

``get_config(name, smoke=...)`` / ``available_archs()`` are the public API.
"""

from repro.configs.base import ModelConfig, available_archs, get_config  # noqa: F401

ASSIGNED_ARCHS = (
    "xlstm-125m",
    "whisper-small",
    "llava-next-34b",
    "llama3.2-1b",
    "deepseek-v3-671b",
    "zamba2-7b",
    "llama4-maverick-400b-a17b",
    "glm4-9b",
    "tinyllama-1.1b",
    "gemma-2b",
)
