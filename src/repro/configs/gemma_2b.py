"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.  Tied embeddings.
Sharding note (DESIGN.md §4): 8 q-heads < 16 model shards, so attention
projections shard on the hidden (n_heads*head_dim) axis and GSPMD resolves
the cross-head split.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        kind="decoder",
        source="arXiv:2403.08295",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp_kind="geglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("gemma-2b", full, smoke)
