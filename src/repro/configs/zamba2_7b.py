"""zamba2-7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Assembly: 13 chunks of (shared attention block -> 6 mamba2 blocks) + 3 tail
mamba2 blocks.  The shared transformer block (one parameter set, reused at
every application) consumes concat(hidden, original embedding), matching
zamba2's design.  SSM state carries the 500k context; shared-attn layers use
the sliding-window variant at long_500k.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        kind="hybrid",
        source="arXiv:2411.15242",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        ssm_state_dim=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        attn_every=6,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=5,          # 2 chunks of 2 + 1 tail
        attn_every=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        ssm_state_dim=16,
        ssm_head_dim=32,
        ssm_chunk=16,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("zamba2-7b", full, smoke)
