"""whisper-small [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  Backbone only: the
mel-spectrogram + conv frontend is a stub supplying (B, 1500, 768) frame
embeddings.  LayerNorm + GELU, learned positions (no RoPE), tied decoder
embedding/unembedding.  ``long_500k`` is skipped for this arch
(DESIGN.md §4: the decoder is bounded by design).
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        kind="encdec",
        source="arXiv:2212.04356",
        num_layers=12,
        encoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        mlp_kind="gelu",
        norm_kind="layernorm",
        use_rope=False,
        max_position_embeddings=448,
        encoder_seq=1500,
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        encoder_seq=12,
        max_position_embeddings=64,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("whisper-small", full, smoke)
