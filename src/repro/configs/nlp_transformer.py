"""The paper's own language-modality model (Appendix A, Fig. 5): a small
transformer classifier used for the AGNews / SogouNews experiments (Table 3).
The paper does not publish exact dims; we use a 4-layer encoder sized to the
reported per-round costs.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nlp-transformer",
        family="dense",
        kind="decoder",          # kind unused by nlp_small; kept for registry shape
        source="paper Fig. 5",
        num_layers=4,
        d_model=256,
        num_heads=8,
        num_kv_heads=8,
        d_ff=1024,
        vocab_size=30000,
        mlp_kind="gelu",
        norm_kind="layernorm",
        use_rope=False,
        max_position_embeddings=256,
    )


def smoke() -> ModelConfig:
    return full().with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=512, max_position_embeddings=64)


register("nlp-transformer", full, smoke)
