"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1 plus
one always-on shared expert (llama4 design).  Assigned as [moe]: the early-
fusion vision path is out of scope (text backbone; DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        kind="decoder",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        num_experts_per_tok=1,
        num_shared_experts=1,
        moe_d_ff=8192,
        capacity_factor=1.25,
        rope_theta=500_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=1,
        moe_d_ff=64,
        capacity_factor=8.0,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("llama4-maverick-400b-a17b", full, smoke)
