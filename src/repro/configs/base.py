"""Model configuration dataclass + registry.

One ``ModelConfig`` describes any architecture in the assigned pool (dense /
MoE / SSM / hybrid / enc-dec audio / VLM) plus the paper's own models.  Every
config module in ``repro/configs/`` registers a full-size config (exact
numbers from the assignment, exercised only via the dry-run) and a ``smoke``
reduced variant (<=2 layers, d_model<=512, <=4 experts) that runs on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
MlpKind = Literal["swiglu", "geglu", "gelu"]
NormKind = Literal["rmsnorm", "layernorm"]
ModelKind = Literal["decoder", "encdec", "xlstm", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str
    family: Family
    kind: ModelKind
    source: str = ""                 # paper / model-card citation

    # trunk ------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    mlp_kind: MlpKind = "swiglu"
    norm_kind: NormKind = "rmsnorm"
    tie_embeddings: bool = False
    rope_theta: float = 500_000.0
    use_rope: bool = True
    max_position_embeddings: int = 0  # >0 -> learned absolute positions
    # Sliding-window attention (0 = full causal).  The long_500k decode shape
    # switches dense/MoE archs to a window (DESIGN.md §4).
    sliding_window: int = 0

    # MoE --------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # leading dense blocks (deepseek-v3: 3)
    router_score: Literal["softmax", "sigmoid"] = "softmax"
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    mtp_depth: int = 0               # deepseek-v3 multi-token prediction heads

    # MLA (deepseek) -----------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # SSM / hybrid -------------------------------------------------------
    ssm_state_dim: int = 0           # mamba2 N
    ssm_num_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0              # zamba2: shared attn block period
    # xlstm: which block index is sLSTM vs mLSTM (alternating by default)
    slstm_every: int = 2             # every 2nd block is sLSTM

    # enc-dec / frontends --------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # stub frontend sequence length (audio frames)
    num_media_tokens: int = 0        # VLM: stub image-embedding tokens per sample

    # numerics -----------------------------------------------------------
    param_dtype: str = "float32"
    activation_dtype: str = "float32"

    # derived --------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def supports_long_decode(self) -> bool:
        """True if the arch can decode with a 500k context (DESIGN.md §4)."""
        if self.kind in ("xlstm", "hybrid"):
            return True
        if self.kind == "encdec":
            return False             # whisper: bounded decoder by design
        return True                  # dense/MoE: sliding-window variant

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(table)}")
    return table[name]()


def available_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # Import every config module for side-effect registration.
    from repro.configs import (  # noqa: F401
        deepseek_v3_671b,
        gemma_2b,
        glm4_9b,
        llama3_2_1b,
        llama4_maverick_400b_a17b,
        llava_next_34b,
        nlp_transformer,
        resnet,
        tinyllama_1_1b,
        whisper_small,
        xlstm_125m,
        zamba2_7b,
    )

    _LOADED = True
