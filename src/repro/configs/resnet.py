"""Paper vision models: ResNet-8 and ResNet-18 (Appendix A).

These are not ``ModelConfig`` transformers — they are registered here for
``--arch`` completeness but the FL engine consumes the specs in
``repro.models.resnet`` directly (RESNET8 / RESNET18).
"""

from repro.configs.base import ModelConfig, register


def _cfg(name: str) -> ModelConfig:
    # Placeholder transformer-shaped record; vision specifics live in
    # repro.models.resnet.  family="dense" keeps registry invariants.
    return ModelConfig(name=name, family="dense", kind="decoder", source="paper App. A")


register("resnet8", lambda: _cfg("resnet8"), lambda: _cfg("resnet8"))
register("resnet18", lambda: _cfg("resnet18"), lambda: _cfg("resnet18"))
