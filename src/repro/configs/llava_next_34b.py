"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  Backbone only: the
vision tower + projector is a stub supplying (B, 576, d_model) patch
embeddings (one base-resolution tile; anyres tiling would multiply the media
token count, noted in DESIGN.md).  Media embeddings occupy the leading
positions of the sequence; labels cover the text positions.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        kind="decoder",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        num_media_tokens=576,
        rope_theta=5_000_000.0,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        num_media_tokens=8,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("llava-next-34b", full, smoke)
