"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0: no separate FFN blocks; the
mLSTM block carries its own 2x up/down projection (xLSTM block design).
Assembly: 6 alternating (mLSTM, sLSTM) pairs.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        kind="xlstm",
        source="arXiv:2405.04517",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm_expand=2,
        ssm_head_dim=192,     # mLSTM: 8 heads of 192 over d_inner=1536; sLSTM: 4 heads over 768
        ssm_chunk=128,
        use_rope=False,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().with_(
        num_layers=2,
        d_model=128,
        vocab_size=512,
        ssm_head_dim=32,
        ssm_chunk=16,
        param_dtype="float32",
        activation_dtype="float32",
    )


register("xlstm-125m", full, smoke)
